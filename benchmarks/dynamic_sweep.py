"""Dynamic-oracle benchmark -> BENCH_dynamic.json.

Replays an interleaved update/query workload on the citeseer analogue
through the incremental path (condensation maintenance + label repair +
versioned publish) and compares against the naive baseline that rebuilds
the index from scratch after every update batch.  Records:

  * repaired updates/sec (apply + publish, epoch per batch),
  * full-rebuild-per-batch baseline updates/sec,
  * query p50/p95 under churn (batched engine path between update batches),
  * the repair-vs-rebuild crossover sweep over batch sizes,
  * a correctness bit: after EVERY batch the served answers are checked
    against a from-scratch rebuild of the mutated graph.

  PYTHONPATH=src python -m benchmarks.dynamic_sweep
  PYTHONPATH=src python -m benchmarks.dynamic_sweep --scale 0.05 --rounds 20
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.build.engine import build_distribution_labels
from repro.core.api import build_oracle
from repro.dynamic import DynamicOracle, generate_trace, replay
from repro.graph.csr import from_edges
from repro.graph.generators import paper_dataset_analogue


def _graph_from_state(delta):
    """Materialize the CURRENT original graph from the update log."""
    src = [u for u in range(delta.n_orig) for _ in delta.out_adj[u]]
    dst = [w for u in range(delta.n_orig) for w in delta.out_adj[u]]
    return from_edges(delta.n_orig, src, dst)


def _check_against_rebuild(dyn, queries, answers, rng, sample: int = 400):
    """Exact agreement vs a from-scratch build_oracle on a query sample.

    Returns the mismatch count — the sweep records it in the JSON and exits
    nonzero at the end rather than aborting mid-run."""
    idx = rng.choice(queries.shape[0], size=min(sample, queries.shape[0]),
                     replace=False)
    fresh = build_oracle(_graph_from_state(dyn.delta))
    exp = fresh.serve(queries[idx])
    got = np.asarray(answers)[idx]
    return int((exp != got).sum())


def run_sweep(dataset: str = "citeseer", scale: float = 0.02, rounds: int = 10,
              updates_per_round: int = 100, queries_per_round: int = 2000,
              insert_frac: float = 0.6, seed: int = 0, check: bool = True,
              crossover_sizes=(10, 50, 100, 500), out=print) -> dict:
    g = paper_dataset_analogue(dataset, scale=scale)
    out(f"graph: {dataset}@{scale} n={g.n} m={g.m}")

    t0 = time.perf_counter()
    dyn = DynamicOracle(g)
    t_init = time.perf_counter() - t0
    out(f"initial build: {t_init:.2f}s  label_ints={dyn.total_label_size}")

    rng = np.random.default_rng(seed)
    errors = [0]

    def _hook(d, q, a):
        errors[0] += _check_against_rebuild(d, q, a, rng) if check else 0

    trace = generate_trace(g, rounds=rounds, updates_per_round=updates_per_round,
                           queries_per_round=queries_per_round,
                           insert_frac=insert_frac, dag_preserving=True, seed=seed)
    # replay times the update and query phases separately; the correctness
    # hook runs after each timed serve call, so its rebuild cost lands in
    # wall time only, never in updates/sec or the query latencies
    stats = replay(dyn, trace, check_truth=_hook if check else None)
    ups = stats.updates_per_sec
    out(f"repaired path: {stats.n_updates} updates in {stats.update_seconds:.3f}s "
        f"-> {ups:,.0f} updates/sec  (repaired={stats.repaired}, "
        f"rebuilds={stats.rebuilds}, structural={stats.structural})")
    if errors[0]:
        out(f"!! dynamic vs rebuild: {errors[0]} mismatched answers")

    # baseline: a full rebuild after every batch (what a static oracle needs)
    reps = 3
    t_rebuild = min(_time_once(lambda: build_distribution_labels(
        dyn.delta.dag_csr())) for _ in range(reps))
    base_ups = updates_per_round / t_rebuild
    out(f"rebuild-per-batch baseline: {t_rebuild:.3f}s/batch "
        f"-> {base_ups:,.0f} updates/sec")
    ratio = ups / base_ups if base_ups else float("inf")
    out(f"repair/rebuild throughput ratio: {ratio:.1f}x")

    p50 = stats.query_pctile(0.5)
    p95 = stats.query_pctile(0.95)
    out(f"query batches under churn: p50={p50 * 1e3:.2f}ms "
        f"p95={p95 * 1e3:.2f}ms per {queries_per_round}-query batch "
        f"({queries_per_round / max(p50, 1e-9) / 1e6:.2f} M qps at p50)")

    # crossover: how repair cost scales with batch size vs one rebuild
    crossover = []
    for bs in crossover_sizes:
        d2 = DynamicOracle(_graph_from_state(dyn.delta))
        tr = generate_trace(_graph_from_state(d2.delta), rounds=2,
                            updates_per_round=bs, queries_per_round=1,
                            insert_frac=insert_frac, dag_preserving=True,
                            seed=seed + bs)
        st = replay(d2, tr)
        per_batch = st.update_seconds / 2
        crossover.append({
            "batch_updates": bs,
            "repair_seconds_per_batch": round(per_batch, 4),
            "rebuild_seconds_per_batch": round(t_rebuild, 4),
            "repair_wins": bool(per_batch < t_rebuild),
        })
        out(f"crossover: batch={bs:>4} repair={per_batch:.3f}s "
            f"rebuild={t_rebuild:.3f}s -> "
            f"{'repair' if per_batch < t_rebuild else 'rebuild'}")

    return {
        "dataset": dataset,
        "scale": scale,
        "n": g.n,
        "m": g.m,
        "rounds": rounds,
        "updates_per_round": updates_per_round,
        "queries_per_round": queries_per_round,
        "insert_frac": insert_frac,
        "initial_build_seconds": round(t_init, 3),
        "label_ints": dyn.total_label_size,
        "repaired": {
            "updates_per_sec": round(ups),
            "update_seconds_total": round(stats.update_seconds, 4),
            "repaired_events": stats.repaired,
            "rebuild_fallbacks": stats.rebuilds,
            "structural_events": stats.structural,
            "epochs_published": stats.epochs,
        },
        "rebuild_baseline": {
            "seconds_per_batch": round(t_rebuild, 4),
            "updates_per_sec": round(base_ups),
        },
        "repair_vs_rebuild_ratio": round(ratio, 2),
        "query_under_churn": {
            "batch": queries_per_round,
            "p50_ms": round(p50 * 1e3, 3),
            "p95_ms": round(p95 * 1e3, 3),
            "mqps_at_p50": round(queries_per_round / max(p50, 1e-9) / 1e6, 3),
        },
        "crossover": crossover,
        "label_growth": _growth_summary(dyn),
        "correctness_vs_rebuild": {
            "checked_after_every_batch": bool(check),
            "mismatches": errors[0],
        },
    }


def _growth_summary(dyn) -> dict:
    """Label-ints growth per epoch (rank-drift observability): repairs
    distribute hops at stale build-time ranks, so a persistently positive
    growth rate flags drift before the staleness budget compacts."""
    gl = dyn.growth_log
    rates = [e["growth_rate"] for e in gl if not e["rebuilt"]]
    return {
        "epochs_published": len(gl),
        "rebuild_publishes": sum(1 for e in gl if e["rebuilt"]),
        "final_label_ints": gl[-1]["label_ints"] if gl else dyn.total_label_size,
        "mean_growth_rate_per_epoch": round(float(np.mean(rates)), 6) if rates else 0.0,
        "max_growth_rate_per_epoch": round(float(np.max(rates)), 6) if rates else 0.0,
        "per_epoch_tail": gl[-10:],
    }


def _time_once(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="citeseer")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--updates-per-round", type=int, default=100)
    ap.add_argument("--queries-per-round", type=int, default=2000)
    ap.add_argument("--insert-frac", type=float, default=0.6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the per-batch rebuild-agreement check")
    ap.add_argument("--json-out", default="BENCH_dynamic.json")
    args = ap.parse_args()
    payload = run_sweep(
        dataset=args.dataset, scale=args.scale, rounds=args.rounds,
        updates_per_round=args.updates_per_round,
        queries_per_round=args.queries_per_round,
        insert_frac=args.insert_frac, seed=args.seed, check=not args.no_check,
    )
    payload["jax_platform"] = __import__("jax").default_backend()
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.json_out}")
    if payload["correctness_vs_rebuild"]["mismatches"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
