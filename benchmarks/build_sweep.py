"""Construction-sweep benchmark -> BENCH_build.json.

Runs the construction engine (wave/bitset) against the scalar reference
builder on the tracked dataset/scale grid and records build time, label
ints, labels/sec, and the byte-identity check per dataset — the
construction-side sibling of ``serve_sweep.py``.

  PYTHONPATH=src python -m benchmarks.build_sweep
  PYTHONPATH=src python -m benchmarks.build_sweep --quick
"""
from __future__ import annotations

import argparse

from benchmarks import construction_time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: one small dataset, one rep "
                         "(writes BENCH_build_quick.json)")
    ap.add_argument("--ci", action="store_true",
                    help="medium-cost CI tier: one mid-size dataset at "
                         "best-of-4 (writes BENCH_build_ci.json)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    if args.json_out is None:
        args.json_out = ("BENCH_build_ci.json" if args.ci
                         else "BENCH_build_quick.json" if args.quick
                         else "BENCH_build.json")
    construction_time._engine_vs_reference_json(args.json_out, quick=args.quick,
                                                ci=args.ci)


if __name__ == "__main__":
    main()
