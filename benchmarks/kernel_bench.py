"""Kernel microbench: interpret-mode wall time is NOT hardware-representative
(TPU is the target); this reports the jnp reference path timings (the XLA-CPU
floor) and validates kernel/ref agreement at bench shapes."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.kernels import ops, ref


def _t(fn, n=5):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jnp.asarray(out).block_until_ready() if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / n


def run(*, out=print):
    out("# kernel_bench (ref-path timings + kernel/ref agreement)")
    out("name,us_per_call,derived")
    rng = np.random.default_rng(0)

    B, L = 8192, 64
    a = rng.integers(0, 1000, size=(B, L)).astype(np.int32)
    b = rng.integers(0, 1000, size=(B, L)).astype(np.int32)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    dt = _t(lambda: ref.label_intersect_ref(aj, bj).block_until_ready())
    agree = bool(
        (np.asarray(ops.label_intersect(aj, bj)) == np.asarray(ref.label_intersect_ref(aj, bj))).all()
    )
    out(csv_row("kernel/label_intersect", dt * 1e6, f"B={B};L={L};kernel_agrees={agree}"))

    n = 1024
    w = (n + 31) // 32
    A = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    X = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    Aj, Xj = jnp.asarray(A), jnp.asarray(X)
    dt = _t(lambda: ref.bitset_mm_ref(Aj, Xj).block_until_ready())
    agree = bool((np.asarray(ops.bitset_mm(Aj, Xj)) == np.asarray(ref.bitset_mm_ref(Aj, Xj))).all())
    out(csv_row("kernel/bitset_mm", dt * 1e6, f"n={n};kernel_agrees={agree}"))

    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)).astype(np.float32))
    dt = _t(lambda: ref.flash_attention_ref(q, k, v, causal=True).block_until_ready())
    kout = np.asarray(ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128))
    agree = bool(np.allclose(kout, np.asarray(ref.flash_attention_ref(q, k, v, causal=True)),
                             rtol=2e-4, atol=2e-4))
    out(csv_row("kernel/flash_attention", dt * 1e6, f"S=1024;GQA4;kernel_agrees={agree}"))

    nbr = rng.integers(0, 4096, size=(4096, 16)).astype(np.int32)
    wgt = rng.standard_normal((4096, 16)).astype(np.float32)
    x = rng.standard_normal((4096, 64)).astype(np.float32)
    nj, wj, xj = jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(x)
    dt = _t(lambda: ref.ell_spmm_ref(nj, wj, xj).block_until_ready())
    out(csv_row("kernel/ell_spmm(ref)", dt * 1e6, "n=4096;deg=16;f=64"))

    table = rng.standard_normal((100_000, 16)).astype(np.float32)
    idx = rng.integers(0, 100_000, size=(8192, 8)).astype(np.int32)
    tj, ij = jnp.asarray(table), jnp.asarray(idx)
    mask = jnp.asarray(idx >= 0)
    dt = _t(lambda: ref.embedding_bag_ref(tj, ij, mask).block_until_ready())
    out(csv_row("kernel/embedding_bag(ref)", dt * 1e6, "V=100k;B=8192;bag=8"))


if __name__ == "__main__":
    run()
